"""Unit tests for the oracle-first, distribution-gated bench harness:
the stats layer (exact percentiles, LogHistogram bucket-edge
semantics, bootstrap CIs) and the Bench arm/gate/trajectory contract
that benchmarks.gates replays from artifacts."""

import math

import pytest

from benchmarks.common import dist_stats
from benchmarks.harness import (
    ALPHA,
    N_BOOT,
    Bench,
    bootstrap_ci,
    bootstrap_ratio_ci,
    ci_verdict,
    pstat,
    replay_gate,
    sample_dist,
)
from repro.sched.telemetry import HIST_BASE_S, LogHistogram, percentile


# ---------------------------------------------------------------------------
# exact percentiles
# ---------------------------------------------------------------------------

def test_percentile_empty_and_single():
    assert percentile([], 50) == 0.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 99) == 7.0


def test_percentile_interpolates():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    # k = 3 * 0.99 = 2.97 -> between s[2] and s[3]
    assert percentile(xs, 99) == pytest.approx(3.97)


# ---------------------------------------------------------------------------
# LogHistogram upper-edge semantics
# ---------------------------------------------------------------------------

def test_loghist_empty_percentile_is_zero():
    assert LogHistogram().percentile(50) == 0.0


def test_loghist_single_sample_clips_edge_to_max():
    # 1.5 ms lands in the (1.024, 2.048] ms bucket; the percentile is
    # the bucket's upper edge clipped to the observed max -> exactly
    # the sample, not the 2.048 ms edge.
    h = LogHistogram()
    h.add(1.5e-3)
    k = LogHistogram.bucket_of(1.5e-3)
    assert LogHistogram.bucket_edge_s(k) == pytest.approx(2.048e-3)
    assert h.percentile(50) == pytest.approx(1.5e-3)
    assert h.percentile(99) == pytest.approx(1.5e-3)


def test_loghist_percentile_is_upper_edge_between_samples():
    # two samples two buckets apart: p50 reports the lower sample's
    # bucket UPPER edge (a <=2x consistent overestimate), p99 clips to
    # the max sample
    h = LogHistogram().extend([1.0e-3, 4.0e-3])
    k_lo = LogHistogram.bucket_of(1.0e-3)
    assert h.percentile(50) == pytest.approx(
        LogHistogram.bucket_edge_s(k_lo))  # 1.024 ms edge, < max
    assert h.percentile(99) == pytest.approx(4.0e-3)


def test_loghist_bucket_geometry():
    # at or below the base lands in bucket 0; each bucket doubles
    assert LogHistogram.bucket_of(HIST_BASE_S) == 0
    assert LogHistogram.bucket_of(HIST_BASE_S * 2) == 1
    assert LogHistogram.bucket_of(HIST_BASE_S * 2.01) == 2


def test_loghist_merge_equals_extend():
    a = LogHistogram().extend([1e-3, 2e-3])
    b = LogHistogram().extend([4e-3, 8e-3])
    merged = a.merge(b)
    whole = LogHistogram().extend([1e-3, 2e-3, 4e-3, 8e-3])
    assert merged.counts == whole.counts
    assert merged.n == whole.n == 4
    assert merged.max == whole.max


def test_dist_stats_uses_histogram_bucketing():
    s = dist_stats([1.5e-3])
    assert s["n"] == 1
    assert s["p50_ms"] == pytest.approx(1.5)
    assert s["tail_p99_p50"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# bootstrap CIs
# ---------------------------------------------------------------------------

def test_bootstrap_ci_deterministic_for_seed():
    xs = [1.0, 1.2, 0.9, 1.1, 1.05]
    a = bootstrap_ci(xs, pstat(50), seed=3)
    b = bootstrap_ci(xs, pstat(50), seed=3)
    c = bootstrap_ci(xs, pstat(50), seed=4)
    assert a == b
    assert a != c  # different seed, different resamples


def test_bootstrap_ci_degenerate_inputs():
    assert bootstrap_ci([], pstat(50)) == (0.0, 0.0)
    assert bootstrap_ci([5.0], pstat(50)) == (5.0, 5.0)
    # constant samples: every resample is identical
    assert bootstrap_ci([2.0] * 8, pstat(99)) == (2.0, 2.0)


def test_bootstrap_ci_covers_true_median_on_synthetic_dist():
    # symmetric synthetic distribution with known median 10.0: the 90%
    # CI of the bootstrap median must contain it, and must be bounded
    # by the sample range
    xs = [8.0, 9.0, 9.5, 10.0, 10.5, 11.0, 12.0]
    lo, hi = bootstrap_ci(xs, pstat(50), seed=0)
    assert lo <= 10.0 <= hi
    assert min(xs) <= lo <= hi <= max(xs)


def test_bootstrap_ci_shifts_with_the_distribution():
    # a real 2x shift moves the whole interval past the old one
    base = [1.0, 1.05, 0.95, 1.02, 0.98]
    shifted = [2.0 * x for x in base]
    _, hi_base = bootstrap_ci(base, pstat(50), seed=0)
    lo_shift, _ = bootstrap_ci(shifted, pstat(50), seed=0)
    assert lo_shift > hi_base


def test_bootstrap_ratio_ci_constant_arms_exact():
    lo, hi = bootstrap_ratio_ci([3.0] * 5, [1.5] * 5, pstat(50))
    assert lo == pytest.approx(2.0)
    assert hi == pytest.approx(2.0)


def test_bootstrap_ratio_ci_empty_arm():
    assert bootstrap_ratio_ci([], [1.0], pstat(50)) == (0.0, 0.0)


# ---------------------------------------------------------------------------
# gate verdict semantics
# ---------------------------------------------------------------------------

def test_ci_verdict_straddle_is_inconclusive_pass():
    assert ci_verdict((0.9, 1.1), "<=", 1.0)   # straddles -> pass
    assert ci_verdict((0.9, 1.1), ">=", 1.0)   # straddles -> pass


def test_ci_verdict_fails_only_on_exclusion():
    assert not ci_verdict((1.2, 1.4), "<=", 1.0)  # whole CI above
    assert ci_verdict((0.5, 0.9), "<=", 1.0)
    assert not ci_verdict((0.5, 0.9), ">=", 1.0)  # whole CI below
    assert ci_verdict((1.2, 1.4), ">=", 1.0)
    # the threshold itself is on the passing side of both ops
    assert ci_verdict((1.0, 1.0), "<=", 1.0)
    assert ci_verdict((1.0, 1.0), ">=", 1.0)


def test_ci_verdict_unknown_op():
    with pytest.raises(ValueError):
        ci_verdict((0.0, 1.0), "==", 1.0)


# ---------------------------------------------------------------------------
# sample_dist
# ---------------------------------------------------------------------------

def test_sample_dist_seconds_includes_histogram():
    d = sample_dist([1e-3, 2e-3, 4e-3], unit="s")
    assert d["n"] == 3
    assert d["latency_hist"]["n"] == 3
    assert d["p50"] == pytest.approx(2e-3)
    assert d["tail_p99_p50"] >= 1.0


def test_sample_dist_other_units_skip_histogram():
    d = sample_dist([1.0, 2.0], unit="steps")
    assert "latency_hist" not in d
    assert d["unit"] == "steps"
    assert sample_dist([], unit="ratio") == {"n": 0, "unit": "ratio"}


# ---------------------------------------------------------------------------
# Bench: arms, oracle equivalence, gates, payload replay
# ---------------------------------------------------------------------------

def test_bench_measure_checks_oracle_equivalence():
    bench = Bench("t", seed=0, repeats=3)
    bench.measure("serial", lambda rep: [1, 2, 3], oracle=True)
    rec = bench.measure("fast", lambda rep: [1, 2, 3],
                        equiv_to="serial")
    assert rec["equiv_ok"] is True
    with pytest.raises(AssertionError, match="fast but wrong"):
        bench.measure("broken", lambda rep: [1, 2],  # dropped an item
                      equiv_to="serial")


def test_bench_measure_custom_check():
    bench = Bench("t", seed=0, repeats=2)
    bench.measure("serial", lambda rep: 100.0, oracle=True)
    rec = bench.measure("approx", lambda rep: 100.0 + 1e-9,
                        equiv_to="serial",
                        check=lambda a, b: math.isclose(a, b))
    assert rec["equiv_ok"] is True


def test_bench_gate_exact_and_check():
    bench = Bench("t", seed=0)
    g = bench.gate_exact("joins", 1, "<=", 1)
    assert g["ok"] and g["ci"] == [1.0, 1.0]
    bench.gate_exact("drops", 3, "<=", 0)
    assert [g["gate"] for g in bench.failed()] == ["drops"]
    with pytest.raises(AssertionError, match="drops"):
        bench.check()


def test_bench_gate_speedup_and_tail():
    bench = Bench("t", seed=0)
    bench.add_samples("serial", [2.0] * 5, oracle=True)
    bench.add_samples("par", [1.0] * 5)
    g = bench.gate_speedup("par", "serial", 1.5)
    assert g["ok"] and g["value"] == pytest.approx(2.0)
    t = bench.gate_tail_ratio("par", 3.0)
    assert t["ok"] and t["value"] == pytest.approx(1.0)


def test_bench_payload_strips_results_and_tracks_trajectory():
    bench = Bench("t", seed=7, repeats=2)
    bench.measure("a", lambda rep: [rep], oracle=True)
    p = bench.payload()
    assert p["seed"] == 7 and p["repeats"] == 2
    assert p["n_boot"] == N_BOOT and p["alpha"] == ALPHA
    assert "_results" not in p["arms"]["a"]
    assert p["arms"]["a"]["samples"]  # raw samples survive for replay
    assert "a.p99_s" in p["trajectory"]
    assert p["trajectory"]["a.p99_s"]["better"] == "lower"


def test_replay_gate_matches_producer_verdict():
    # the round-trip contract: replaying a stored gate from the
    # artifact's raw samples reproduces the producer's CI exactly
    bench = Bench("t", seed=5)
    bench.add_samples("serial", [2.0, 2.1, 1.9, 2.05, 1.95], oracle=True)
    bench.add_samples("par", [1.0, 1.1, 0.9, 1.05, 0.95])
    bench.gate_speedup("par", "serial", 1.5)
    bench.gate_tail_ratio("par", 3.0)
    bench.gate_samples("par_p50", "par", "<=", 2.0)
    payload = bench.payload()
    for stored in payload["gates"]:
        replayed = replay_gate(stored, payload["arms"])
        assert replayed["ok"] == stored["ok"]
        assert replayed["ci"] == pytest.approx(stored["ci"])


def test_replay_gate_unknown_kind():
    with pytest.raises(ValueError):
        replay_gate({"kind": "mystery"}, {})
