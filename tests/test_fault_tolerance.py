"""Fault-tolerance integration: checkpoint atomicity, failure injection +
exact resume, elastic restore under a different mesh, data-pipeline
restart determinism, DLBC pool behaviour."""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.data.pool import DLBCPool
from repro.train.trainer import (
    SimulatedFailure, TrainerConfig, run_training,
)


@pytest.fixture()
def tmpdir():
    d = tempfile.mkdtemp()
    yield d
    shutil.rmtree(d, ignore_errors=True)


def test_checkpoint_roundtrip_bf16(tmpdir):
    mgr = CheckpointManager(tmpdir, keep=2)
    tree = {"a": jnp.arange(8, dtype=jnp.bfloat16),
            "b": {"c": jnp.ones((3, 3), jnp.float32)}}
    mgr.save(5, tree, blocking=True)
    step, out = mgr.restore()
    assert step == 5
    assert out["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                  np.arange(8, dtype=np.float32))


def test_checkpoint_gc_and_latest(tmpdir):
    mgr = CheckpointManager(tmpdir, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.zeros(2)}, blocking=True)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_incomplete_checkpoint_ignored(tmpdir):
    mgr = CheckpointManager(tmpdir, keep=3)
    mgr.save(1, {"x": jnp.ones(2)}, blocking=True)
    # fake a torn write: a step dir without COMMIT
    (mgr.dir / "step_0000000002").mkdir()
    assert mgr.latest_step() == 1


def test_failure_injection_and_exact_resume(tmpdir):
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    shape = ShapeConfig("s", 64, 4, "train", microbatches=2)
    with pytest.raises(SimulatedFailure):
        run_training(cfg, shape, TrainerConfig(
            steps=8, ckpt_every=2, ckpt_dir=tmpdir, failure_at=5))
    rep = run_training(cfg, shape, TrainerConfig(
        steps=8, ckpt_every=2, ckpt_dir=tmpdir))
    assert rep.resumed_from == 4
    assert rep.completed == 8
    # compare against an uninterrupted run: the resumed run's final eval
    # loss must match bitwise (same data replay, same updates)
    d2 = tempfile.mkdtemp()
    try:
        ref = run_training(cfg, shape, TrainerConfig(
            steps=8, ckpt_every=100, ckpt_dir=d2))
        assert rep.losses[-1] == pytest.approx(ref.losses[-1], abs=1e-5)
    finally:
        shutil.rmtree(d2, ignore_errors=True)


def test_elastic_restore_resharding(tmpdir):
    """A checkpoint written un-meshed restores onto a 4-device mesh —
    the elastic-restart path (device count change across restarts)."""
    import subprocess, sys, textwrap, os

    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.checkpoint import CheckpointManager

        mgr = CheckpointManager({tmpdir!r})
        mgr.save(1, {{"w": jnp.arange(16.0).reshape(4, 4)}}, blocking=True)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        shard = {{"w": NamedSharding(mesh, P("data", "model"))}}
        step, out = mgr.restore(shardings=shard)
        assert step == 1
        assert out["w"].sharding.num_devices == 4, out["w"].sharding
        np.testing.assert_array_equal(
            np.asarray(out["w"]), np.arange(16.0).reshape(4, 4))
        print("ELASTIC_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.getcwd())
    assert "ELASTIC_OK" in out.stdout, out.stdout + out.stderr


def test_data_pipeline_restart_determinism():
    cfg = DataConfig(seq_len=32, global_batch=8, vocab=100, seed=7,
                     n_shards=4)
    p1 = SyntheticPipeline(cfg)
    p2 = SyntheticPipeline(cfg)
    for step in (0, 3, 17):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps differ
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_dlbc_pool_executes_all_and_balances():
    pool = DLBCPool(n_workers=3)
    try:
        done = []
        import threading

        lock = threading.Lock()

        def fn(i):
            with lock:
                done.append(i)

        pool.run_loop(list(range(50)), fn)
        assert sorted(done) == list(range(50))
        assert pool.stats.joins >= 1
        assert pool.stats.tasks_spawned <= 3  # ≤ idle workers
    finally:
        pool.shutdown()


def test_dlbc_pool_serial_fallback():
    """With zero workers the loop must still complete serially."""
    pool = DLBCPool(n_workers=1)
    try:
        # occupy the single worker
        import threading, time

        release = threading.Event()
        ev = threading.Event()
        pool._q.put((lambda: release.wait(2), ev))
        time.sleep(0.05)
        done = []
        pool.run_loop(list(range(10)), done.append)
        release.set()
        assert sorted(done) == list(range(10))
        assert pool.stats.serial_items >= 1
    finally:
        pool.shutdown()
