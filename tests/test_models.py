"""Per-architecture smoke tests: reduced configs, one forward/train step
and one decode step on CPU; output shapes + finiteness asserted.
(Full configs are exercised only by the dry-run — ShapeDtypeStruct only.)"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeConfig
from repro.models import model as MDL
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import StepConfig, build_decode_step, \
    build_train_step

B, S = 2, 64


def _batch(cfg):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        b["enc_frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.family == "vlm":
        b["vis_embed"] = jnp.zeros((B, cfg.vis_seq, cfg.d_model),
                                   jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    scfg = StepConfig(q_chunk=32, k_chunk=32, ssm_chunk=16)
    step, _ = build_train_step(cfg, ShapeConfig("t", S, B, "train", 2),
                               scfg, AdamWConfig())
    opt = init_opt_state(params, AdamWConfig())
    p2, o2, m = jax.jit(step)(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(m["grad_norm"]))
    assert int(o2["step"]) == 1
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert l0.shape == l1.shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    logits = MDL.forward(params, cfg, _batch(cfg), q_chunk=32, k_chunk=32,
                         ssm_chunk=16, remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    cache = MDL.init_cache(cfg, B, 64)
    serve = build_decode_step(cfg)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32),
             "cache_index": jnp.asarray(3, jnp.int32)}
    logits, cache2 = jax.jit(serve)(params, cache, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    # cache must actually advance
    flat0 = jax.tree.leaves(cache)
    flat1 = jax.tree.leaves(cache2)
    assert any(
        not jnp.array_equal(a, b) for a, b in zip(flat0, flat1))


def test_decode_matches_forward_dense():
    """Token-by-token decode reproduces the forward pass logits."""
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    T = 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab)
    full = MDL.forward(params, cfg, {"tokens": tokens}, q_chunk=8,
                       k_chunk=8, remat=False).astype(jnp.float32)
    cache = MDL.init_cache(cfg, 1, 16)
    outs = []
    for t in range(T):
        logits, cache = MDL.decode_step(
            params, cfg, cache,
            {"tokens": tokens[:, t:t + 1],
             "cache_index": jnp.asarray(t, jnp.int32)})
        outs.append(logits.astype(jnp.float32))
    import numpy as np

    dec = jnp.stack(outs, axis=1)  # (1, T, V)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=0.15, rtol=0.15)
    # argmax agreement is the functional bar (bf16 params)
    assert bool(jnp.all(jnp.argmax(dec, -1) == jnp.argmax(full, -1)))


def test_decode_matches_forward_ssm():
    cfg = get_config("falcon-mamba-7b", smoke=True)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    T = 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab)
    full = MDL.forward(params, cfg, {"tokens": tokens}, ssm_chunk=4,
                       remat=False).astype(jnp.float32)
    cache = MDL.init_cache(cfg, 1, 16)
    outs = []
    for t in range(T):
        logits, cache = MDL.decode_step(
            params, cfg, cache,
            {"tokens": tokens[:, t:t + 1],
             "cache_index": jnp.asarray(t, jnp.int32)})
        outs.append(logits.astype(jnp.float32))
    dec = jnp.stack(outs, axis=1)
    assert bool(jnp.all(jnp.argmax(dec, -1) == jnp.argmax(full, -1)))


def test_moe_dispatch_dlbc_drops_fewer():
    import dataclasses

    from repro.models import moe as MOE

    cfg = get_config("mixtral-8x7b", smoke=True)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    base = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.d_model))
    x = jnp.repeat(base, 64, axis=0) + 0.05 * jax.random.normal(
        jax.random.PRNGKey(2), (512, cfg.d_model))
    drops = {}
    for dispatch in ("lc", "dlbc"):
        c = dataclasses.replace(cfg, moe_dispatch=dispatch,
                                moe_capacity_factor=1.0)
        _, stats = MOE.moe_apply(p, c, x, return_stats=True)
        drops[dispatch] = float(stats["dropped_frac"])
    assert drops["dlbc"] < drops["lc"]


def test_moe_matches_ref_when_capacity_ample():
    """With enough capacity both dispatchers equal the dense oracle."""
    import dataclasses

    import numpy as np

    from repro.models import moe as MOE

    cfg = dataclasses.replace(get_config("granite-moe-1b-a400m", smoke=True),
                              moe_capacity_factor=8.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    ref = MOE.moe_ref(p, cfg, x)
    for dispatch in ("lc", "dlbc"):
        c = dataclasses.replace(cfg, moe_dispatch=dispatch)
        y, stats = MOE.moe_apply(p, c, x, return_stats=True)
        assert float(stats["dropped_frac"]) == 0.0
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=2e-4, rtol=2e-3)
