"""Adoption of repro.sched on the train-step, checkpoint, and MoE
surfaces: async checkpoint overlap + single-join semantics, chunk-plan
gradient bucketing vs the fixed-bucket oracle, expert-capacity admission,
and the kernel/einsum dispatch equivalence."""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.sched import DLBC, ExpertCapacityProvider, FixedCapacity
from repro.train.train_step import StepConfig, _bucketize, build_train_step


@pytest.fixture()
def tmpdir():
    d = tempfile.mkdtemp()
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _tree():
    return {f"layer_{i}": {"w": jnp.full((32, 32), float(i)),
                           "b": jnp.zeros((32,))}
            for i in range(12)}


# ---------------------------------------------------------------------------
# Checkpoint surface: DCAFE shard writes, one join per save
# ---------------------------------------------------------------------------


def test_ckpt_async_save_overlaps_and_joins_once(tmpdir):
    """``save(blocking=False)`` returns with the publish still pending
    (the escaped finish), the trainer overlaps its next step, and
    ``wait()`` performs exactly ONE join before the atomic publish."""
    mgr = CheckpointManager(tmpdir, sched_policy="dcafe")
    try:
        mgr.save(3, _tree(), blocking=False)
        # not yet published: the join (and the COMMIT) belong to wait()
        assert mgr.telemetry.joins == 0
        assert mgr.latest_step() is None
        # ... a concurrently running "train step" on the main thread ...
        x = jnp.ones((64, 64))
        jax.block_until_ready(x @ x)
        mgr.wait()
        assert mgr.telemetry.joins == 1      # the single escaped finish
        assert mgr.telemetry.spawns >= 1     # shard writes were spawned
        assert mgr.latest_step() == 3
        step, out = mgr.restore()
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(out["layer_5"]["w"]), np.full((32, 32), 5.0))
        # wait() is idempotent: no second join for the same save
        mgr.wait()
        assert mgr.telemetry.joins == 1
    finally:
        mgr.close()


def test_ckpt_stealing_executor_same_contract(tmpdir):
    """The adaptive work-stealing substrate is a drop-in for the shard
    writes: one escaped join per save, atomic publish, identical restore
    — with the grain decided by the policy's controller (spawns stay
    O(ranges), bounded by the shard count)."""
    from repro.sched import WorkStealingExecutor

    mgr = CheckpointManager(tmpdir, sched_policy="dcafe", stealing=True)
    try:
        mgr.save(7, _tree(), blocking=True)
        assert isinstance(mgr.executor, WorkStealingExecutor)
        t = mgr.telemetry
        assert t.joins == 1
        assert 1 <= t.spawns <= 24  # ranges (+ any splits), not per-shard
        assert t.completions == t.spawns
        assert mgr.latest_step() == 7
        step, out = mgr.restore()
        assert step == 7
        np.testing.assert_array_equal(
            np.asarray(out["layer_5"]["w"]), np.full((32, 32), 5.0))
    finally:
        mgr.close()


def test_global_pool_stealing_opt_in(monkeypatch):
    """``global_pool(stealing=True)`` serves the work-stealing substrate
    through the same wrapper surface (first creation wins)."""
    import repro.data.pool as pool_mod

    monkeypatch.setattr(pool_mod, "_GLOBAL", None)
    pool = pool_mod.global_pool(n_workers=2, stealing=True)
    try:
        assert isinstance(pool, pool_mod.StealingPool)
        done = []
        import threading
        lock = threading.Lock()

        def fn(i):
            with lock:
                done.append(i)

        pool.run_loop(list(range(20)), fn)
        assert sorted(done) == list(range(20))
        assert pool.stats.completions == pool.stats.spawns
    finally:
        pool.shutdown()
        monkeypatch.setattr(pool_mod, "_GLOBAL", None)


def test_ckpt_restore_only_manager_spawns_no_pool(tmpdir):
    """The I/O pool is lazy: a manager used only for restore/inspection
    never starts worker threads (and close() is a no-op)."""
    mgr = CheckpointManager(tmpdir)
    assert mgr._ex is None
    assert mgr.latest_step() is None
    mgr.close()
    assert mgr._ex is None


def test_ckpt_failed_shard_write_never_commits(tmpdir, monkeypatch):
    """A shard write failing PERSISTENTLY on a worker must abort the
    publish: the bounded RetryPolicy exhausts its attempts, wait()
    raises and no COMMIT (hence no 'latest' checkpoint) appears.
    (Transient single-shot faults are retried and recover — see
    test_faults.py.)"""
    import repro.ckpt.checkpoint as CKPT

    real_save = np.save
    calls = {"n": 0}

    def flaky_save(fname, arr, *a, **k):
        calls["n"] += 1
        if calls["n"] >= 3:  # persistent from the 3rd write on
            raise OSError("disk full")
        return real_save(fname, arr, *a, **k)

    monkeypatch.setattr(CKPT.np, "save", flaky_save)
    mgr = CheckpointManager(tmpdir, sched_policy="dcafe")
    try:
        mgr.save(1, _tree(), blocking=False)
        with pytest.raises(RuntimeError, match="shard"):
            mgr.wait()
        assert mgr.latest_step() is None  # torn save stayed un-COMMITted
    finally:
        mgr.close()  # must not re-raise the consumed publish failure


def test_ckpt_lc_policy_joins_per_save(tmpdir):
    """The LC baseline joins inside every save — the contrast the
    adoption benchmark's DCAFE<=LC gate rests on."""
    mgr = CheckpointManager(tmpdir, sched_policy="lc")
    try:
        for s in (1, 2):
            mgr.save(s, _tree(), blocking=True)
        assert mgr.telemetry.joins == 2
        assert mgr.all_steps() == [1, 2]
    finally:
        mgr.close()


# ---------------------------------------------------------------------------
# Train-step surface: chunk-plan gradient bucketing
# ---------------------------------------------------------------------------


def _grads():
    rng = np.random.default_rng(0)
    return {
        "emb": jnp.asarray(rng.normal(size=(128, 16)), jnp.float32),
        "l0": {"w": jnp.asarray(rng.normal(size=(64, 8)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(64,)), jnp.float32)},
        "l1": {"w": jnp.asarray(rng.normal(size=(32, 8)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)},
        "head": jnp.asarray(rng.normal(size=(16, 128)), jnp.float32),
    }


def test_bucketize_all_busy_matches_fixed_bucket_oracle():
    """With zero idle reduction streams DLBC takes the serial arm, which
    must partition leaves identically to the fixed-bucket LPT oracle."""
    grads = _grads()
    leaves = jax.tree.leaves(grads)
    flat_o, unflat_o = _bucketize(grads, 4)
    flat_s, unflat_s = _bucketize(grads, 4, policy=DLBC(),
                                  capacity=FixedCapacity(0, 4))
    b_o, b_s = flat_o(leaves), flat_s(leaves)
    assert len(b_o) == len(b_s)
    for a, b in zip(b_o, b_s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucketize_plan_driven_caller_keeps_smallest():
    """With idle streams the bucket count comes from the chunk plan and
    the caller's (last) bucket holds the smallest leaves."""
    grads = _grads()
    leaves = jax.tree.leaves(grads)
    n = 3
    flat, unflat = _bucketize(grads, n, policy="dlbc")
    buckets = flat(leaves)
    assert len(buckets) == n  # chunk_plan over 6 leaves, 3 streams
    # idle-worker-aware: fewer idle reduction streams → fewer buckets
    flat2, _ = _bucketize(grads, 4, policy=DLBC(),
                          capacity=FixedCapacity(1, 4))
    assert len(flat2(leaves)) == 2  # 1 idle stream + the caller
    # every element exactly once
    assert sum(b.size for b in buckets) == sum(l.size for l in leaves)
    # caller bucket is the plan's smallest chunk of the size-ordered
    # leaf list → it cannot hold more payload than any spawned bucket
    assert buckets[-1].size == min(b.size for b in buckets)
    # round trip
    out = unflat(buckets)
    for k_path, a in zip(jax.tree.leaves(grads), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(k_path), np.asarray(a))


def test_build_train_step_sched_counts_ladder():
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.train.optimizer import AdamWConfig

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train", microbatches=4)
    counts = {}
    for pol in ("serial", "lc", "dlbc", "dcafe"):
        scfg = StepConfig(policy="afe_bucket", sched_policy=pol,
                          q_chunk=32, k_chunk=32, ssm_chunk=16)
        step, _ = build_train_step(cfg, shape, scfg, AdamWConfig())
        counts[pol] = step.sched_counts
    assert counts["serial"]["spawns"] == 0
    assert counts["serial"]["mb_unroll"] == 1
    assert counts["lc"]["spawns"] > 0
    assert counts["dlbc"]["spawns"] > 0
    # DCAFE chunks exactly like DLBC but escapes the per-step join
    assert counts["dcafe"]["spawns"] == counts["dlbc"]["spawns"]
    assert counts["dlbc"]["joins"] == 1 and counts["dcafe"]["joins"] == 0
    assert counts["dcafe"]["escape_join"]


# ---------------------------------------------------------------------------
# MoE surface: expert-capacity admission + kernel dispatch path
# ---------------------------------------------------------------------------


def test_expert_capacity_provider_arithmetic():
    cap = ExpertCapacityProvider(n_experts=4, slots_per_expert=8)
    assert cap.total() == 32
    assert cap.idle() == 32
    pos = jnp.asarray([[0, 7], [8, 3]])
    np.testing.assert_array_equal(
        np.asarray(cap.admit_mask(pos)),
        np.asarray([[True, True], [False, True]]))
    load = jnp.asarray([0, 8, 12, 5])
    np.testing.assert_array_equal(
        np.asarray(cap.residual(load)), np.asarray([8, 0, 0, 3]))


@pytest.mark.parametrize("dispatch", ["lc", "dlbc"])
def test_moe_apply_stats_sched_vocabulary(dispatch):
    import dataclasses

    from repro.configs import get_config
    from repro.models import moe as MOE

    cfg = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                              moe_dispatch=dispatch)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    y, stats = MOE.moe_apply(p, cfg, x, return_stats=True)
    assert y.shape == x.shape
    # spawns (admitted pairs) + drops account for every (token, choice)
    total_pairs = 64 * cfg.top_k
    spawns = int(stats["spawns"])
    dropped = float(stats["dropped_frac"]) * total_pairs
    assert spawns + round(dropped) == total_pairs
    assert int(stats["joins"]) == 1
    assert stats["rounds"] == (1 if dispatch == "lc" else 2)


def test_moe_kernel_dispatch_matches_einsum_path():
    """The Pallas grouped-matmul dispatch path (use_kernel=True,
    interpret on CPU) agrees with the XLA einsum path."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import moe as MOE

    cfg = get_config("mixtral-8x7b", smoke=True)
    assert cfg.act == "swiglu"
    cfg = dataclasses.replace(cfg, moe_dispatch="dlbc")
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, cfg.d_model)) * 0.5
    y_xla = MOE.moe_apply(p, cfg, x)
    y_krn = MOE.moe_apply(p, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_krn),
                               atol=2e-4, rtol=2e-4)
