"""Unit tests for the DCAFE mini-transformations (paper Figs. 2/4/8/9),
including the Fig. 5 running example and the exception-extended variants."""

import pytest

from repro.core.afe import apply_afe
from repro.core.analysis import Summaries, bound_locals
from repro.core.errors import ExcValue
from repro.core.ir import (
    Assign, Async, Call, Compute, Finish, ForLoop, If, MethodDef, Program,
    Seq, Skip, Throw, TryCatch, binop, const, expr, seq, var, walk,
)
from repro.core.runtime import run_program
from repro.core.transforms import (
    Ctx, async_finish_interchange, finish_expansion_lower,
    finish_expansion_upper, finish_fusion_pair, finish_if_interchange,
    loop_finish_interchange, rewrite_fixpoint, tail_finish_elimination,
)


def bump(name, amount=1, cost=0.1):
    return Compute(
        fn=lambda env, _n=name, _a=amount: env.set_heap(_n, env[_n] + _a),
        reads=frozenset({f"{name}[+]"}), writes=frozenset({f"{name}[+]"}),
        cost=cost, label=f"{name}+={amount}")


def read_into(dst, src, cost=0.1):
    return Compute(
        fn=lambda env, _d=dst, _s=src: env.set_heap(_d, env[_s]),
        reads=frozenset({src}), writes=frozenset({dst}), cost=cost,
        label=f"{dst}={src}")


def ctx_for(prog, method="main", no_exc=False):
    s = Summaries.compute(prog)
    m = prog.method(method)
    return Ctx(summaries=s, assume_no_exceptions=no_exc,
               private=frozenset(m.params) | bound_locals(m.body))


def count_finishes(stmt):
    return sum(1 for n in walk(stmt) if isinstance(n, Finish))


def run_heap(prog, heap, workers=3):
    r = run_program(prog, n_workers=workers, heap=dict(heap))
    assert r.ok, r.error
    return r.heap, r


# ---------------------------------------------------------------------------
# Individual rules (exception-free forms)
# ---------------------------------------------------------------------------


def prog_of(body, extra_methods=()):
    return Program(methods=(MethodDef(name="main", params=(), body=body),)
                   + tuple(extra_methods))


def test_loop_finish_interchange():
    body = ForLoop(loopvar="i", lo=const(0), hi=const(4), step=const(1),
                   body=Finish(body=Async(body=bump("x"))))
    p = prog_of(body)
    out = loop_finish_interchange(body, ctx_for(p))
    assert isinstance(out, Finish)
    assert count_finishes(out) == 1
    h1, _ = run_heap(p, {"x": 0})
    h2, _ = run_heap(prog_of(out), {"x": 0})
    assert h1["x"] == h2["x"] == 4


def test_finish_fusion():
    a = Finish(body=Async(body=bump("x")))
    b = Finish(body=Async(body=bump("y")))
    p = prog_of(Seq((a, b)))
    fused = finish_fusion_pair(a, b, ctx_for(p))
    assert fused is not None and count_finishes(fused) == 1
    h, _ = run_heap(prog_of(fused), {"x": 0, "y": 0})
    assert h["x"] == 1 and h["y"] == 1


def test_finish_fusion_blocked_by_dependence():
    a = Finish(body=Async(body=read_into("y", "x")))
    # second finish body reads y which the first's e-async writes
    b = Finish(body=read_into("z", "y"))
    p = prog_of(Seq((a, b)))
    assert finish_fusion_pair(a, b, ctx_for(p)) is None


def test_tail_finish_elimination():
    s = Finish(body=Finish(body=Async(body=bump("x"))))
    p = prog_of(s)
    out = tail_finish_elimination(s, ctx_for(p))
    assert out is not None and count_finishes(out) == 1


def test_finish_if_interchange():
    s = If(cond=expr(lambda env: env["flag"] > 0, "flag", label="flag>0"),
           then=Finish(body=Async(body=bump("x"))))
    p = prog_of(s)
    out = finish_if_interchange(s, ctx_for(p))
    assert out is not None
    # v = cond; finish { if (v) ... }
    h, _ = run_heap(prog_of(out), {"flag": 1, "x": 0})
    assert h["x"] == 1
    h, _ = run_heap(prog_of(out), {"flag": 0, "x": 0})
    assert h["x"] == 0


def test_finish_expansion_upper_lower():
    s1 = bump("a")
    f = Finish(body=Async(body=bump("x")))
    s2 = bump("b")
    p = prog_of(Seq((s1, f, s2)))
    up = finish_expansion_upper(s1, f, ctx_for(p))
    assert isinstance(up, Finish)
    low = finish_expansion_lower(f, s2, ctx_for(p))
    assert isinstance(low, Finish)


def test_finish_expansion_lower_blocked_by_dependence():
    f = Finish(body=Async(body=read_into("y", "x")))
    s2 = read_into("z", "y")
    p = prog_of(Seq((f, s2)))
    assert finish_expansion_lower(f, s2, ctx_for(p)) is None


def test_async_finish_interchange():
    s = Async(body=Finish(body=Async(body=bump("x"))))
    p = prog_of(Finish(body=s))
    out = async_finish_interchange(s, ctx_for(p))
    assert isinstance(out, Finish)
    assert isinstance(out.body, Async)


# ---------------------------------------------------------------------------
# Fig. 5 running example: fixpoint rewrite collapses to one finish
# ---------------------------------------------------------------------------


def test_fig5_running_example():
    # S1; finish{S2}; if(c){ finish{ async{ finish{ for{ finish S3 } } } } }; finish{S4}
    s3 = Finish(body=Async(body=bump("s3")))
    inner_loop = ForLoop(loopvar="i", lo=const(0), hi=const(3),
                         step=const(1), body=s3)
    body = seq(
        bump("s1"),
        Finish(body=Async(body=bump("s2"))),
        If(cond=expr(lambda env: env["c"] > 0, "c", label="c>0"),
           then=Finish(body=Async(body=Finish(body=inner_loop)))),
        Finish(body=Async(body=bump("s4"))),
    )
    p = prog_of(body)
    ctx = ctx_for(p, no_exc=True)
    out = rewrite_fixpoint(body, ctx)
    assert count_finishes(out) < count_finishes(body)
    h1, r1 = run_heap(p, {"s1": 0, "s2": 0, "s3": 0, "s4": 0, "c": 1})
    h2, r2 = run_heap(prog_of(out), {"s1": 0, "s2": 0, "s3": 0, "s4": 0,
                                     "c": 1})
    for k in ("s1", "s2", "s3", "s4"):
        assert h1[k] == h2[k]
    assert r2.counters.finishes <= r1.counters.finishes


# ---------------------------------------------------------------------------
# Exceptions (Figs. 8/9 semantics)
# ---------------------------------------------------------------------------


def test_exception_in_async_wrapped_as_me():
    body = TryCatch(
        body=Finish(body=Async(body=Throw(exc_type="Ex"))),
        exc_var="e",
        handler=Compute(
            fn=lambda env: env.set_heap(
                "caught",
                tuple(sorted(x.type_name for x in env["e"].flatten()))),
            reads=frozenset({"e"}), writes=frozenset({"caught"}), cost=0.0,
            label="record"),
        exc_types=("ME", "Exception"),
    )
    h, r = run_heap(prog_of(body), {"caught": None})
    assert h["caught"] == ("Ex",)


def test_expansion_upper_exception_variant_preserves_semantics():
    # S1 throws; finish{S2} — after the transform the exception must still
    # escape un-wrapped and S2 must not run.
    s1 = If(cond=expr(lambda env: env["boom"] > 0, "boom", label="boom"),
            then=Throw(exc_type="Ex"))
    f = Finish(body=Async(body=bump("x")))
    p = prog_of(seq(
        TryCatch(body=Seq((s1, f)), exc_var="e",
                 handler=bump("caught"), exc_types=("Ex",)),
    ))
    ctx = ctx_for(p)
    out = rewrite_fixpoint(p.method("main").body, ctx)
    p2 = p.with_method(MethodDef(name="main", params=(), body=out))
    from repro.core.ir import lower_program_pending

    p2 = lower_program_pending(p2)
    for boom in (0, 1):
        h1, _ = run_heap(p, {"x": 0, "caught": 0, "boom": boom})
        h2, _ = run_heap(p2, {"x": 0, "caught": 0, "boom": boom})
        assert h1["x"] == h2["x"], boom
        assert h1["caught"] == h2["caught"], boom


def test_afe_with_exceptions_nqueens_like():
    """A recursive kernel whose tasks may throw: AFE must keep semantics
    (gex protocol) while still reducing finishes where legal."""
    rec_body = Finish(
        body=ForLoop(
            loopvar="i", lo=const(0), hi=const(2), step=const(1),
            body=Async(body=seq(
                bump("work"),
                If(cond=expr(lambda env: env["d"] + 1 < 3, "d",
                             label="d+1<3"),
                   then=Call(callee="rec",
                             args=(binop("+", var("d"), const(1)),))),
            )),
        )
    )
    rec = MethodDef(name="rec", params=("d",), body=rec_body)
    main = MethodDef(name="main", params=(),
                     body=Call(callee="rec", args=(const(0),)))
    p = Program(methods=(main, rec))
    p2, report = apply_afe(p)
    h1, r1 = run_heap(p, {"work": 0})
    h2, r2 = run_heap(p2, {"work": 0})
    assert h1["work"] == h2["work"]
    assert r2.counters.finishes <= r1.counters.finishes
