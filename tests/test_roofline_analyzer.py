"""Regression tests for the trip-count-aware HLO analyzer — the §Roofline
methodology (cost_analysis counts scan bodies once; we must not)."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_analyzer import analyze_hlo


def _scan_matmul_hlo(n_iters, m=128, k=256, n=256):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=n_iters)
        return out

    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ).compile().as_text()


def test_exact_dot_flops():
    cost = analyze_hlo(_scan_matmul_hlo(1))
    assert cost.flops == pytest.approx(2 * 128 * 256 * 256, rel=1e-6)


@pytest.mark.parametrize("trips", [4, 32])
def test_trip_count_scaling(trips):
    base = analyze_hlo(_scan_matmul_hlo(1)).flops
    scaled = analyze_hlo(_scan_matmul_hlo(trips)).flops
    assert scaled == pytest.approx(trips * base, rel=1e-6)


def test_xla_cost_analysis_undercounts_scans():
    """The motivating defect: XLA reports identical FLOPs for 1 and 32
    scan iterations.  If this ever starts failing, XLA fixed it and the
    analyzer can be simplified."""
    def f(x, w, n):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=n)
        return out

    def cost(n):
        import functools

        ca = jax.jit(functools.partial(f, n=n)).lower(
            jax.ShapeDtypeStruct((128, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 256), jnp.float32),
        ).compile().cost_analysis()
        # cost_analysis() returned a one-dict list on older jax releases
        # and a plain dict on newer ones.
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return ca.get("flops", 0)

    assert cost(1) == cost(32)


def test_collective_detection():
    hlo = """
HloModule test, entry_computation_layout={()->f32[8]{0}}

ENTRY %main () -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ag = f32[64]{0} all-gather(%p), replica_groups={}, dimensions={0}
  ROOT %ar = f32[8]{0} all-reduce(%p), to_apply=%add
}
"""
    cost = analyze_hlo(hlo)
    assert cost.coll_count.get("all-gather") == 1
    assert cost.coll_bytes.get("all-gather") == 64 * 4
    assert cost.coll_count.get("all-reduce") == 1
