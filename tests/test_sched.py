"""repro.sched: chunk-plan properties, policy decisions, executor
equivalence with the pre-refactor pool/batcher behaviour, telemetry."""

import threading

import pytest

from repro.sched import (
    DCAFE, DLBC, LC, ChunkPlan, FixedCapacity, GrainController, GrainPlan,
    RangeLatch, Serial, SlotExecutor, ThreadExecutor, WorkStealingExecutor,
    chunk_plan, get_policy, percentile, static_plan,
)
from repro.sched.telemetry import SchedTelemetry


# ---------------------------------------------------------------------------
# chunk_plan properties (exhaustive over a grid — property-style without
# requiring hypothesis)
# ---------------------------------------------------------------------------


GRID = [(lo, lo + n, idle)
        for lo in (0, 3, 17)
        for n in range(0, 41)
        for idle in range(0, 8)]


def test_chunk_plan_partitions_range_exactly():
    for lo, hi, idle in GRID:
        plan = chunk_plan(lo, hi, idle)
        pos = lo
        for a, b in plan.chunks:
            assert a == pos and b >= a, (lo, hi, idle, plan)
            pos = b
        assert pos == hi, (lo, hi, idle, plan)


def test_chunk_plan_caller_keeps_smallest():
    for lo, hi, idle in GRID:
        plan = chunk_plan(lo, hi, idle)
        caller_sz = plan.caller[1] - plan.caller[0]
        assert caller_sz == (hi - lo) // (idle + 1)
        for a, b in plan.spawned:
            assert b - a >= caller_sz


def test_chunk_plan_remainder_spread_from_front():
    """First ``n % tot`` spawned chunks get exactly one extra iteration."""
    for lo, hi, idle in GRID:
        n, tot = hi - lo, idle + 1
        eq, r = divmod(n, tot)
        plan = chunk_plan(lo, hi, idle)
        sizes = [b - a for a, b in plan.spawned]
        if eq > 0:
            assert sizes == [eq + 1] * r + [eq] * (tot - 1 - r), \
                (lo, hi, idle, sizes)
        else:
            # fewer items than workers: one item per spawned chunk,
            # nothing left for the caller
            assert sizes == [1] * r
            assert plan.caller[0] == plan.caller[1]


def test_chunk_plan_all_spawned_variant():
    for lo, hi, idle in GRID:
        plan = chunk_plan(lo, hi, idle, caller_keeps_smallest=False)
        assert plan.caller[0] == plan.caller[1]
        assert sum(b - a for a, b in plan.spawned) == hi - lo


def test_static_plan_ceil_chunks():
    for lo, hi, nchunks in [(0, 10, 4), (5, 6, 4), (0, 0, 3), (2, 33, 5)]:
        plan = static_plan(lo, hi, nchunks)
        assert plan.caller == (hi, hi)
        pos = lo
        for a, b in plan.spawned:
            assert a == pos and b > a
            pos = b
        assert pos == hi
        assert len(plan.spawned) <= nchunks


# ---------------------------------------------------------------------------
# Grain plans (adaptive work stealing)
# ---------------------------------------------------------------------------


def test_grain_controller_initial_grain_formula():
    """initial = ceil(n / (k·workers)), floored at min_grain."""
    c = GrainController(k=2, min_grain=1)
    assert c.plan(64, 4).initial == 8
    assert c.plan(65, 4).initial == 9   # ceil
    assert c.plan(3, 4).initial == 1
    assert c.plan(0, 4).initial is None  # nothing to carve
    c = GrainController(k=1, min_grain=4)
    assert c.plan(10, 8).initial == 4   # min_grain floor


def test_grain_controller_validates():
    with pytest.raises(ValueError):
        GrainController(k=0)
    with pytest.raises(ValueError):
        GrainController(k=4, k_max=2)


def test_grain_controller_escalates_on_skewed_steals_only():
    """The feedback loop: a steal burst with skewed item costs halves the
    grain (k doubles); the same burst with uniform costs is churn and
    must decay k back instead."""
    tel = SchedTelemetry()
    for ms in (1.0, 1.0, 1.0, 5.0) * 8:      # skewed: p90/p50 = 5
        tel.record_latency(ms / 1e3)
    c = GrainController(k=1, k_max=8)
    c.plan(64, 4, tel)                        # first read: baseline only
    tel.steals += 10                          # hungry workers, skewed costs
    c.plan(64, 4, tel)
    assert c.k == 2
    tel.steals += 10
    c.plan(64, 4, tel)
    assert c.k == 4

    # now uniform latencies: steals keep coming but they are churn
    tel.latencies.clear()
    for _ in range(64):
        tel.record_latency(1e-3)
    tel.steals += 10
    c.plan(64, 4, tel)
    assert c.k == 3                           # decays toward k0
    for _ in range(3):
        tel.steals += 10
        c.plan(64, 4, tel)
    assert c.k == 1                           # back to coarse


def test_dlbc_grain_plan_routes_through_controller():
    cap = FixedCapacity(idle_n=3, total_n=4)
    pol = DLBC(grain=GrainController(k=2, split_min=3))
    gp = pol.grain_plan(64, cap)
    assert gp == GrainPlan(initial=8, split_min=3)
    # base policies keep whole-chunk lazily-split ranges
    assert Serial().grain_plan(64, cap) == GrainPlan()
    assert LC().grain_plan(64, cap) == GrainPlan()
    assert DCAFE().grain_plan(64, cap).initial is not None  # inherits DLBC


def test_wdlbc_grain_plan_delegates_to_base():
    from repro.sched.tenancy import WeightedRefillPolicy

    cap = FixedCapacity(idle_n=3, total_n=4)
    w = WeightedRefillPolicy(base=DLBC(grain=GrainController(k=4)))
    assert w.grain_plan(64, cap) == GrainPlan(initial=4, split_min=2)


def test_range_latch_counts_down_and_is_event_compatible():
    latch = RangeLatch(3)
    assert not latch.is_set()
    latch.discharge(2)
    assert not latch.wait(timeout=0.01)
    latch.discharge(1)
    assert latch.is_set() and latch.wait(timeout=0)
    assert RangeLatch(0).is_set()  # empty range joins immediately


def test_telemetry_recent_skew():
    tel = SchedTelemetry()
    assert tel.recent_skew() == 1.0  # too few samples to judge
    for _ in range(32):
        tel.record_latency(1e-3)
    assert tel.recent_skew() == pytest.approx(1.0)
    for _ in range(8):
        tel.record_latency(10e-3)  # a recent heavy tail
    assert tel.recent_skew() > 2.0


# ---------------------------------------------------------------------------
# Policy decisions
# ---------------------------------------------------------------------------


def test_dlbc_decides_parallel_iff_idle():
    pol = DLBC()
    d = pol.decide(0, 100, FixedCapacity(idle_n=3, total_n=4))
    assert d.plan is not None and len(d.plan.spawned) == 3
    d = pol.decide(0, 100, FixedCapacity(idle_n=0, total_n=4))
    assert d.plan is None and d.recheck_every == 1


def test_serial_never_parallel_never_rechecks():
    d = Serial().decide(0, 100, FixedCapacity(idle_n=4, total_n=4))
    assert d.plan is None and d.recheck_every == 0


def test_lc_ignores_idleness():
    d = LC().decide(0, 100, FixedCapacity(idle_n=0, total_n=4))
    assert d.plan is not None
    assert len(d.plan.spawned) == 4  # total workers, not idle
    assert d.plan.caller == (100, 100)  # caller only joins


def test_get_policy_resolution():
    assert get_policy("dcafe").escape_join
    assert not get_policy("dlbc").escape_join
    p = DLBC(serial_check_every=4)
    assert get_policy(p) is p
    with pytest.raises(ValueError):
        get_policy("nope")


# ---------------------------------------------------------------------------
# ThreadExecutor ≡ old DLBCPool (spawn/join counts preserved)
# ---------------------------------------------------------------------------


def test_thread_executor_counts_match_prerefactor_pool():
    """On an all-idle pool of W workers the old DLBCPool spawned exactly
    the Fig. 6 chunk count and performed one join; via-sched must agree."""
    for w, n in [(3, 50), (4, 9), (2, 1), (4, 100)]:
        ex = ThreadExecutor(n_workers=w)
        try:
            lock = threading.Lock()
            done = []

            def fn(i):
                with lock:
                    done.append(i)

            ex.run_loop(list(range(n)), fn)
            assert sorted(done) == list(range(n))
            expect = chunk_plan(0, n, w)  # all W workers were idle
            assert ex.telemetry.spawns == len(expect.spawned)
            assert ex.telemetry.spawns <= w
            assert ex.telemetry.joins == 1
            assert ex.telemetry.parallel_items == n
            # old PoolStats field names still readable
            assert ex.telemetry.tasks_spawned == ex.telemetry.spawns
        finally:
            ex.shutdown()


def test_thread_executor_serial_fallback_counts():
    """With the single worker occupied, items run in the serial block with
    per-item re-probe — same as the old pool's serial arm."""
    import time

    ex = ThreadExecutor(n_workers=1)
    try:
        release = threading.Event()
        ev = ex._submit(lambda: release.wait(2))
        time.sleep(0.05)
        done = []
        ex.run_loop(list(range(10)), done.append)
        release.set()
        ev.wait(2)
        assert sorted(done) == list(range(10))
        assert ex.telemetry.serial_items >= 1
    finally:
        ex.shutdown()


def test_dlbc_pool_wrapper_is_thread_executor():
    from repro.data.pool import DLBCPool

    pool = DLBCPool(n_workers=2)
    try:
        done = []
        lock = threading.Lock()

        def fn(i):
            with lock:
                done.append(i)

        pool.run_loop(list(range(20)), fn)
        assert sorted(done) == list(range(20))
        assert pool.stats.joins == 1
        assert pool.stats.tasks_spawned <= 2
        assert isinstance(pool, ThreadExecutor)
    finally:
        pool.shutdown()


def test_run_loop_by_name_policy_state_persists():
    """By-name policies are cached per executor, so the DLBC grain
    controller's steal-feedback baseline survives across loops — a
    fresh instance per loop would make the adaptive-grain feedback
    structurally inert on every zero-config surface."""
    ex = WorkStealingExecutor(n_workers=2)
    try:
        ex.run_loop(list(range(8)), lambda i: None)          # None → dlbc
        ex.run_loop(list(range(8)), lambda i: None, policy="dlbc")
        pol = ex._policy_cache["dlbc"]
        assert isinstance(pol, DLBC)
        # the controller observed the first loops: baseline recorded
        assert pol.grain._last_steals is not None
        # instance-passed policies are untouched by the cache
        mine = DLBC()
        ex.run_loop(list(range(8)), lambda i: None, policy=mine)
        assert ex._policy_cache["dlbc"] is pol
    finally:
        ex.shutdown()


def test_work_stealing_executor_runs_all_items():
    ex = WorkStealingExecutor(n_workers=3)
    try:
        lock = threading.Lock()
        done = []

        def fn(i):
            with lock:
                done.append(i)

        for _ in range(3):
            ex.run_loop(list(range(40)), fn)
        assert sorted(done) == sorted(list(range(40)) * 3)
        assert ex.telemetry.joins == 3
    finally:
        ex.shutdown()


def test_dcafe_scope_single_join_many_loops():
    ex = ThreadExecutor(n_workers=2)
    try:
        lock = threading.Lock()
        out = []

        def fn(i):
            with lock:
                out.append(i)

        with ex.finish() as scope:
            for _ in range(4):
                ex.run_loop(list(range(8)), fn, policy="dcafe", scope=scope)
        assert len(out) == 32
        assert ex.telemetry.joins == 1  # the aggressive-finish-elimination win
        assert ex.telemetry.spawns >= 4
    finally:
        ex.shutdown()


# ---------------------------------------------------------------------------
# SlotExecutor ≡ old batcher admission logic
# ---------------------------------------------------------------------------


def _old_admit(policy, slot_req, queue, n_slots):
    """The pre-refactor ContinuousBatcher._admit, as a reference oracle."""
    placements = []
    idle = [i for i, r in enumerate(slot_req) if r is None]
    if policy == "dlbc":
        for slot in idle:
            if not queue:
                break
            placements.append((slot, queue.pop(0)))
    else:  # lc
        if len(idle) == n_slots and len(queue) > 0:
            for slot in idle:
                if not queue:
                    break
                placements.append((slot, queue.pop(0)))
    return placements


@pytest.mark.parametrize("policy", ["dlbc", "lc"])
def test_slot_refill_matches_prerefactor_batcher(policy):
    cases = [
        ([None, None, None, None], list("abcdef")),
        ([None, "X", None, "Y"], list("abc")),
        (["X", "Y", "Z", "W"], list("ab")),
        ([None, None, None, None], []),
        ([None, "X", None, None], list("a")),
        ([None, None], list("abc")),
    ]
    for slots, queue in cases:
        q_old, q_new = list(queue), list(queue)
        want = _old_admit(policy, slots, q_old, len(slots))
        ex = SlotExecutor(len(slots), policy=policy)
        got = ex.refill(slots, q_new)
        assert got == want, (policy, slots, queue)
        assert q_new == q_old
        assert ex.telemetry.spawns == len(want)


def test_slot_executor_counts_joins_on_complete():
    ex = SlotExecutor(4, policy="dlbc")
    ex.refill([None] * 4, list("abcd"))
    for lat in (3.0, 7.0):
        ex.complete(latency_steps=lat)
    assert ex.telemetry.spawns == 4
    assert ex.telemetry.joins == 2
    assert ex.telemetry.p50() == 5.0


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------


def test_percentile_interpolation():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.5
    assert percentile(list(map(float, range(1, 101))), 99) == pytest.approx(
        99.01)


def test_telemetry_json_roundtrip():
    import json

    t = SchedTelemetry()
    t.spawns = 5
    t.joins = 1
    t.record_latency(0.010)
    t.record_latency(0.030)
    d = json.loads(t.to_json())
    assert d["spawns"] == 5 and d["joins"] == 1
    assert d["p50_ms"] == pytest.approx(20.0)
    t.reset()
    assert t.spawns == 0 and not t.latencies


def test_sim_counters_share_sched_vocabulary():
    from repro.core.runtime import Counters
    from repro.sched.telemetry import SchedCounters

    c = Counters()
    assert isinstance(c, SchedCounters)
    c.asyncs += 3
    c.finishes += 1
    assert c.spawns == 3 and c.joins == 1
    assert c.as_dict()["asyncs"] == 3
