"""repro.obs metrics plane + monitor: registry snapshots/deltas, the
flight recorder's windowed crosscheck, the per-tenant SLO burn-rate
monitor, and the stall watchdog under seeded fault injection."""

import json
import time
from contextlib import nullcontext

import pytest

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import monitor as obs_monitor
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry, Snapshotter
from repro.obs.monitor import (
    FlightRecorder, SloMonitor, StallWatchdog, recording,
)
from repro.sched import (
    MultipleExceptions, SchedTelemetry, WorkStealingExecutor,
)
from repro.sched.faults import FaultPlan, FaultSpec, injected_faults


@pytest.fixture(autouse=True)
def _obs_reset():
    """Metrics stay enabled (the default-on contract), the tracer stays
    off, and no recorder leaks between tests."""
    obs_metrics.enable()
    obs.disable()
    obs.clear()
    obs_monitor.uninstall()
    yield
    obs_metrics.enable()
    obs.disable()
    obs.clear()
    obs_monitor.uninstall()


# -- metrics registry ---------------------------------------------------------

def test_counter_gauge_histogram_snapshot_delta():
    reg = MetricsRegistry()
    c, g, h = reg.counter("t.c"), reg.gauge("t.g"), reg.histogram("t.h_s")
    c.inc(3)
    g.set(7.5)
    h.observe(1e-3)
    older = reg.snapshot()
    c.inc(2)
    g.set(9.0)
    h.observe(5e-2)
    h.observe(5e-2)
    d = reg.snapshot().delta(older)
    assert d["counters"]["t.c"] == 2
    assert d["gauges"]["t.g"] == 9.0
    w = d["hists"]["t.h_s"]
    # only the window's two 50ms observations, not the cumulative three
    assert w["n"] == 2
    assert 50.0 <= w["p50_ms"] <= 110.0
    assert d["rates"]["t.c"] > 0


def test_registry_handles_are_singletons():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.gauge("y") is reg.gauge("y")
    assert reg.histogram("z") is reg.histogram("z")


def test_disable_stops_bumps():
    reg = MetricsRegistry()
    c = reg.counter("d.c")
    c.inc()
    obs_metrics.disable()
    c.inc(100)
    reg.gauge("d.g").set(5.0)
    reg.histogram("d.h").observe(1.0)
    obs_metrics.enable()
    snap = reg.snapshot()
    assert snap.counters["d.c"] == 1
    assert snap.gauges["d.g"] == 0.0
    assert snap.hists["d.h"].n == 0


def test_pull_source_sampled_into_gauges():
    reg = MetricsRegistry()
    reg.add_source("tel", lambda: {"spawns": 4, "joins": 4})
    snap = reg.snapshot()
    assert snap.gauges["tel.spawns"] == 4
    reg.remove_source("tel")
    assert "tel.spawns" not in reg.snapshot().gauges


def test_broken_source_reports_not_raises():
    reg = MetricsRegistry()
    reg.add_source("bad", lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap.gauges["bad.source_error"] == 1.0


def test_snapshotter_sample_and_jsonl(tmp_path):
    reg = MetricsRegistry()
    c = reg.counter("s.c")
    path = tmp_path / "metrics.jsonl"
    snap = Snapshotter(reg, interval_s=60.0, path=str(path), capacity=4)
    snap.start()
    try:
        c.inc(5)
        rec = snap.sample()
        assert rec["counters"]["s.c"] == 5
        c.inc(2)
        rec = snap.sample()
        assert rec["counters"]["s.c"] == 2  # the window, not cumulative
        for _ in range(10):
            snap.sample()
        assert len(snap.records) == 4  # bounded ring
    finally:
        snap.stop()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) >= 12
    assert lines[0]["counters"]["s.c"] == 5


def test_executor_feeds_default_registry():
    before = obs_metrics.snapshot()
    ex = WorkStealingExecutor(n_workers=2)
    try:
        ex.run_loop(list(range(16)), lambda x: x)
    finally:
        ex.shutdown()
    d = obs_metrics.snapshot().delta(before)
    assert d["counters"]["sched.loops"] == 1
    assert d["counters"]["sched.items"] == 16
    assert d["hists"]["sched.loop_s"]["n"] == 1


# -- flight recorder ----------------------------------------------------------

def test_record_requires_known_trigger():
    rec = FlightRecorder()
    with pytest.raises(ValueError):
        rec.record("made_up", "nope")


def test_record_basic_report_and_persistence(tmp_path):
    tel = SchedTelemetry()
    rec = FlightRecorder(telemetry=tel, out_dir=str(tmp_path))
    rec.arm()
    tel.spawns += 3
    tel.joins += 1
    rep = rec.record("join_stall", "test stall", scope="s", site="x",
                     extra={"pending": 2})
    assert rep["schema"] == obs_monitor.INCIDENT_SCHEMA
    assert rep["trigger"] == "join_stall"
    assert rep["implicated"] == {"scope": "s", "site": "x"}
    assert rep["telemetry_window"]["spawns"] == 3
    assert rep["telemetry_window"]["joins"] == 1
    assert rec.count() == 1 and rec.count("join_stall") == 1
    files = list(tmp_path.glob("incident-*.json"))
    assert len(files) == 1
    on_disk = json.loads(files[0].read_text())
    assert on_disk["trigger"] == "join_stall"


def test_rate_limit_suppresses_refire():
    rec = FlightRecorder(min_interval_s=60.0)
    assert rec.record("ep_degraded", "one") is not None
    assert rec.record("ep_degraded", "two") is None  # suppressed
    assert rec.record("join_stall", "other trigger") is not None
    assert rec.count() == 2


def test_windowed_crosscheck_on_traced_incident():
    obs.enable()
    tel = SchedTelemetry()
    ex = WorkStealingExecutor(n_workers=2, telemetry=tel)
    rec = FlightRecorder(telemetry=tel)
    try:
        ex.run_loop(list(range(32)), lambda x: x)  # pre-window noise
        rec.arm()  # clears rings + baselines counters HERE
        ex.run_loop(list(range(16)), lambda x: x)
        rep = rec.record("join_stall", "synthetic window test")
    finally:
        ex.shutdown()
    # the window covers only the second loop, and its embedded trace
    # must re-derive exactly the windowed counter delta
    assert rep["crosscheck"]["ok"], rep["crosscheck"]["mismatches"]
    assert rep["telemetry_window"]["spawns"] > 0
    assert (rep["telemetry_window"]["spawns"]
            < tel.counters_snapshot()["spawns"])


def test_join_failure_fires_multiple_exceptions_incident():
    tel = SchedTelemetry()
    ex = WorkStealingExecutor(n_workers=2, telemetry=tel)
    plan = FaultPlan([FaultSpec(site="sched.item", kind="raise", every=4)],
                     seed=7)
    rec = FlightRecorder(telemetry=tel)
    try:
        with recording(rec), injected_faults(plan):
            rec.arm()
            with pytest.raises(MultipleExceptions):
                with ex.finish() as scope:
                    ex.run_loop(list(range(16)), lambda x: None,
                                scope=scope)
    finally:
        ex.shutdown()
    assert rec.count("multiple_exceptions") == 1
    (rep,) = rec.incidents
    assert rep["extra"]["error_count"] == plan.injected_total(kind="raise")
    assert rep["telemetry_window"]["errors"] == rep["extra"]["error_count"]


def test_no_recorder_installed_hooks_are_noops():
    # the default-off contract: hooks cost one global read and return
    obs_monitor.on_join_failed(object(), 3)
    obs_monitor.on_join_timeout(object(), 1, 0.5)
    obs_monitor.on_ep_degraded({2, 0})


def test_ep_degraded_hook_shapes_report():
    rec = FlightRecorder()
    with recording(rec):
        obs_monitor.on_ep_degraded({3, 1}, round_errors=2)
    (rep,) = rec.incidents
    assert rep["trigger"] == "ep_degraded"
    assert rep["implicated"]["shard"] == 1
    assert rep["extra"]["dead_shards"] == [1, 3]
    assert rep["extra"]["round_errors"] == 2


# -- stall watchdog -----------------------------------------------------------

SEEDS = range(5)


def _run_watched(plan, deadline_s, n_items=32, item_s=1e-4):
    """One executor pass with the scope under watchdog watch; returns
    (watchdog, recorder, telemetry)."""
    tel = SchedTelemetry()
    ex = WorkStealingExecutor(n_workers=2, telemetry=tel)
    rec = FlightRecorder(telemetry=tel)
    dog = StallWatchdog(recorder=rec, poll_s=0.005)
    try:
        with injected_faults(plan) if plan is not None else nullcontext():
            with ex.finish() as scope:
                dog.watch(scope, deadline_s, label="test-scope")
                # dcafe: the join escapes into the watched scope, so
                # pending() reflects the in-flight chunk waitables
                ex.run_loop(n_items * [item_s], time.sleep,
                            policy="dcafe", scope=scope)
        dog.scan()  # quiesced scopes drop from the watch list
    finally:
        dog.stop()
        ex.shutdown()
    return dog, rec, tel


@pytest.mark.parametrize("seed", SEEDS)
def test_watchdog_clean_run_no_false_positives(seed):
    dog, rec, _ = _run_watched(None, deadline_s=30.0)
    assert dog.fired == 0
    assert rec.count() == 0


@pytest.mark.parametrize("seed", SEEDS)
def test_watchdog_slow_fault_fires_exactly_once(seed):
    # one injected 0.3s stall vs a 0.05s join deadline: the watchdog
    # must fire exactly one join_stall incident, every seed
    plan = FaultPlan([FaultSpec(site="sched.item", kind="slow",
                                delay_s=0.3, every=1, max_injections=1)],
                     seed=seed)
    dog, rec, _ = _run_watched(plan, deadline_s=0.05)
    assert dog.fired == 1
    assert rec.count("join_stall") == 1
    (rep,) = rec.incidents
    assert rep["implicated"]["scope"] == "test-scope"
    assert rep["extra"]["pending"] >= 1


@pytest.mark.parametrize("seed", SEEDS)
def test_watchdog_worker_death_recovers_before_deadline(seed):
    # a worker dies, but recovery re-places its queued work well inside
    # the generous deadline: the death is counted, no stall incident —
    # the watchdog watches outcomes, not failures
    plan = FaultPlan([FaultSpec(site="sched.worker", kind="worker_death",
                                every=1, max_injections=1)], seed=seed)
    dog, rec, tel = _run_watched(plan, deadline_s=30.0)
    assert tel.worker_deaths == 1
    assert dog.fired == 0
    assert rec.count() == 0


def test_watchdog_scan_is_deterministic_without_thread():
    class _Stuck:
        def pending(self):
            return 2

    rec = FlightRecorder()
    dog = StallWatchdog(recorder=rec, poll_s=3600.0)  # thread inert
    dog.watch(_Stuck(), deadline_s=0.0, label="stuck")
    time.sleep(0.001)  # move past the zero deadline
    assert dog.scan() == 1
    assert dog.scan() == 0  # at most once per watched scope
    assert rec.count("join_stall") == 1
    dog.stop()


# -- SLO burn-rate monitor ----------------------------------------------------

class _FakeStats:
    def __init__(self):
        self.decode_step_costs = []
        self.failed = 0
        self.expired = 0


class _FakeTenant:
    def __init__(self, slo_cost=0.0):
        self.queue = []
        self.slo_cost = slo_cost


class _FakeRegistry:
    def __init__(self, tenants):
        self._tenants = tenants

    def names(self):
        return list(self._tenants)

    def get(self, name):
        return self._tenants[name]


class _FakeBatcher:
    """The duck-typed surface SloMonitor.observe consumes."""

    def __init__(self, slos, slo_cost=0.0):
        self.slos = slos
        self.registry = _FakeRegistry(
            {n: _FakeTenant(slo_cost) for n in slos})
        self.tenant_stats = {n: _FakeStats() for n in slos}
        self.stats = _FakeStats()
        self.queue = []

    def _slo_of(self, name):
        return self.slos.get(name, 0)


def test_slo_monitor_clean_burns_nothing():
    rec = FlightRecorder()
    mon = SloMonitor(recorder=rec, budget_frac=0.1, horizon=20)
    b = _FakeBatcher({"steady": 40})
    for step in range(50):
        b.tenant_stats["steady"].decode_step_costs.append(1.0)
        mon.observe(b, step)
    t = mon.summary()["tenants"]["steady"]
    assert t["bad_steps"] == 0 and t["budget_spent"] == 0.0
    assert rec.count() == 0


def test_slo_monitor_burn_fires_exactly_once():
    rec = FlightRecorder()
    mon = SloMonitor(recorder=rec, budget_frac=0.1, horizon=20)  # allow 2
    b = _FakeBatcher({"steady": 40})  # derived ceiling max(2, 10) = 10
    st = b.tenant_stats["steady"]
    fired_at = None
    for step in range(12):
        st.decode_step_costs.append(50.0)  # every step is bad
        mon.observe(b, step)
        if fired_at is None and mon.incidents_fired:
            fired_at = step
    assert fired_at == 2  # 3rd bad step exceeds the 2-step budget
    assert mon.incidents_fired == 1  # never re-fires
    assert rec.count("slo_burn") == 1
    (rep,) = rec.incidents
    assert rep["implicated"]["tenant"] == "steady"
    assert rep["extra"]["burn_rate"] > 1.0
    assert rep["extra"]["bad_steps"] == 3


def test_slo_monitor_explicit_cost_ceiling_wins():
    mon = SloMonitor(budget_frac=0.5, horizon=4)
    b = _FakeBatcher({"steady": 40}, slo_cost=100.0)
    st = b.tenant_stats["steady"]
    for step in range(10):
        st.decode_step_costs.append(50.0)  # under the explicit ceiling
        mon.observe(b, step)
    assert mon.summary()["tenants"]["steady"]["bad_steps"] == 0


def test_slo_monitor_failures_count_as_bad_steps():
    rec = FlightRecorder()
    mon = SloMonitor(recorder=rec, budget_frac=0.25, horizon=4)  # allow 1
    b = _FakeBatcher({"steady": 40})
    st = b.tenant_stats["steady"]
    for step in range(4):
        st.decode_step_costs.append(1.0)  # cost is fine...
        st.failed += 1                    # ...but a request failed
        mon.observe(b, step)
    t = mon.summary()["tenants"]["steady"]
    assert t["bad_steps"] == 4
    assert rec.count("slo_burn") == 1


def test_slo_monitor_ignores_unslod_tenants():
    mon = SloMonitor(budget_frac=0.1, horizon=10)
    b = _FakeBatcher({"free": 0})
    b.tenant_stats["free"].decode_step_costs.append(1000.0)
    mon.observe(b, 0)
    assert mon.summary()["tenants"] == {}


def test_slo_monitor_deterministic_across_seeds():
    # same trace, same verdict: the burn step is a pure function of the
    # cost sequence (no wall-clock in the accounting)
    outcomes = set()
    for seed in SEEDS:
        mon = SloMonitor(budget_frac=0.1, horizon=20)
        b = _FakeBatcher({"steady": 40})
        st = b.tenant_stats["steady"]
        for step in range(30):
            st.decode_step_costs.append(50.0 if step % 3 == 0 else 1.0)
            mon.observe(b, step)
        t = mon.summary()["tenants"]["steady"]
        outcomes.add((t["bad_steps"], t["first_burn_step"]))
    assert len(outcomes) == 1  # identical on every run
